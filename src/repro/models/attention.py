"""Attention: GQA/MHA, RoPE/M-RoPE, sliding-window, blockwise (flash-style)
training/prefill path and cached decode path. Pure JAX + lax control flow.

Memory discipline follows the paper's VWR staging idea: the sequence is
walked in fixed-size chunks ("VWR fills"); the online-softmax accumulator
plays the role of the in-register partial result, so the full (S x S) score
matrix is never materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import P, fanin_std


# ---------------------------------------------------------------------------
# Schema (with grouped head padding)
# ---------------------------------------------------------------------------

def padded_heads(cfg) -> tuple[int, int]:
    """(H_padded, group_padded): pad the per-KV-group query-head count so
    H_padded = KV * G_p is divisible by cfg.tp_pad. Head index layout is
    kv-major (h = kv * G_p + g) so GQA grouping survives the padding."""
    H, KV, tp = cfg.num_heads, cfg.num_kv_heads, max(1, cfg.tp_pad)
    G = H // KV
    Gp = G
    while (KV * Gp) % tp:
        Gp += 1
    return KV * Gp, Gp


def head_mask(cfg, dtype=jnp.float32):
    """(H_padded,) 1.0 for real heads, 0.0 for padding."""
    Hp, Gp = padded_heads(cfg)
    G = cfg.num_heads // cfg.num_kv_heads
    m = (np.arange(Hp) % Gp) < G
    return jnp.asarray(m, dtype)


def attention_schema(cfg):
    d, KV, dh = cfg.d_model, cfg.num_kv_heads, cfg.hd
    Hp, _ = padded_heads(cfg)
    s = {
        "wq": P((d, Hp, dh), ("embed", "heads", "head_dim"), fanin_std(d)),
        "wk": P((d, KV, dh), ("embed", "kv_heads", "head_dim"), fanin_std(d)),
        "wv": P((d, KV, dh), ("embed", "kv_heads", "head_dim"), fanin_std(d)),
        "wo": P((Hp, dh, d), ("heads", "head_dim", "embed"),
                fanin_std(cfg.num_heads * dh)),
    }
    if cfg.qkv_bias:
        s["bq"] = P((Hp, dh), ("heads", "head_dim"), 0.0)
        s["bk"] = P((KV, dh), ("kv_heads", "head_dim"), 0.0)
        s["bv"] = P((KV, dh), ("kv_heads", "head_dim"), 0.0)
    if cfg.proj_bias:
        s["bo"] = P((d,), ("embed",), 0.0)
    return s


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _inv_freq(dh: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, dh, 2, dtype=np.float64) / dh))


def _mrope_segments(dh: int, sections) -> np.ndarray:
    """Map each rotary frequency index to a position stream (0=t,1=h,2=w)."""
    n = dh // 2
    total = sum(sections)
    counts = [int(round(n * s / total)) for s in sections]
    counts[0] = n - sum(counts[1:])
    return np.repeat(np.arange(len(sections)), counts)


def apply_rope(x, positions, *, theta, style="neox", sections=(2, 1, 1)):
    """x: (B, S, H, dh). positions: (B,S) int32 or (B,S,3) for mrope."""
    if style == "none":
        return x
    dh = x.shape[-1]
    inv = jnp.asarray(_inv_freq(dh, theta), jnp.float32)  # (dh/2,)
    if style == "mrope":
        seg = jnp.asarray(_mrope_segments(dh, sections))  # (dh/2,)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(seg, positions.shape[:-1] + seg.shape),
            axis=-1,
        )  # (B,S,dh/2) — per-frequency position stream
        ang = pos * inv
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B,S,dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — train / prefill
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def blockwise_attention(q, k, v, *, causal=True, window=None,
                        q_chunk=1024, kv_chunk=1024):
    """Online-softmax chunked attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh) with H % KV == 0.
    Returns (B, Sq, H, dh). Never materializes (Sq x Skv).
    Off-band chunks are skipped with lax.cond (real compute saving under jit).
    """
    B, Sq, H, dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    sq_valid, skv_valid = Sq, Skv
    if Sq % qc:  # pad queries (rows discarded at the end)
        q = jnp.pad(q, ((0, 0), (0, -Sq % qc), (0, 0), (0, 0)))
        Sq = q.shape[1]
    if Skv % kc:  # pad keys/values (masked out below)
        k = jnp.pad(k, ((0, 0), (0, -Skv % kc), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, -Skv % kc), (0, 0), (0, 0)))
        Skv = k.shape[1]
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / np.sqrt(dh)
    # chunk index of the diagonal for causal masking (prefill: Sq == Skv)
    q_of_k = qc  # q positions advance qc per chunk

    qr = q.reshape(B, nq, qc, KV, G, dh)
    kr = k.reshape(B, nk, kc, KV, dh)
    vr = v.reshape(B, nk, kc, KV, dh)

    q_pos = jnp.arange(Sq).reshape(nq, qc)
    k_pos = jnp.arange(Skv).reshape(nk, kc)

    if window is not None:
        lo_chunk = lambda i, j: j * kc >= (i * qc - (window - 1) - (kc - 1))
    else:
        lo_chunk = lambda i, j: True

    def q_block(args):
        i, qb = args  # qb: (B, qc, KV, G, dh)
        qb32 = qb.astype(jnp.float32) * scale

        def kv_step(carry, j):
            m, l, acc = carry

            def compute(_):
                kb = kr[:, j].astype(jnp.float32)  # (B,kc,KV,dh)
                vb = vr[:, j].astype(jnp.float32)
                s = jnp.einsum("bqkgd,bskd->bkgqs", qb32, kb)  # (B,KV,G,qc,kc)
                mask = k_pos[j][None, :] < skv_valid  # (1, kc) kv-pad mask
                mask = jnp.broadcast_to(mask, (qc, kc))
                if causal:
                    mask &= q_pos[i][:, None] >= k_pos[j][None, :]
                if window is not None:
                    mask &= q_pos[i][:, None] - k_pos[j][None, :] < window
                s = jnp.where(mask, s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bkgqs,bskd->bkgqd", p, vb
                )
                return m_new, l_new, acc_new

            live = jnp.asarray(lo_chunk(i, j), bool)
            if causal:
                live &= jnp.asarray(j * kc <= i * q_of_k + (qc - 1))
            return jax.lax.cond(live, compute, lambda _: (m, l, acc), None), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,qc,dh)
        return out.transpose(0, 3, 1, 2, 4)  # (B,qc,KV,G,dh)

    outs = jax.lax.map(q_block, (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    # outs: (nq, B, qc, KV, G, dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, dh)
    return out[:, :sq_valid].astype(q.dtype)


# ---------------------------------------------------------------------------
# Cached decode attention (one new token)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """q: (B,1,H,dh); caches: (B,S,KV,dh); positions > cache_len masked.
    cache_len: scalar or (B,) vector (per-slot continuous batching).

    With the cache sequence-sharded over the model axis, the max/sum
    reductions below become the flash-decoding partial-softmax combine
    (XLA SPMD inserts the small all-reduces of m and l).
    """
    B, _, H, dh = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / np.sqrt(dh)
    cl = jnp.broadcast_to(jnp.atleast_1d(cache_len), (B,))
    qr = q.reshape(B, KV, G, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = pos[None, :] <= cl[:, None]  # cache_len = index of the new token
    if window is not None:
        mask &= pos[None, :] > cl[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def decode_attention_ring(q, k_cache, v_cache, cl):
    """Sliding-window decode over a RING cache of W slots: slot i holds the
    key of absolute position p == i (mod W), p <= cache_len. All slots are
    in-window once warm; cold slots (p would be negative) are masked."""
    B, _, H, dh = q.shape
    _, W, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / np.sqrt(dh)
    qr = q.reshape(B, KV, G, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    slots = jnp.arange(W)[None, :]                      # (1, W)
    # absolute position held by slot i: largest p <= cl with p % W == i
    abs_pos = cl[:, None] - ((cl[:, None] - slots) % W)
    mask = abs_pos >= 0
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged cache views
# ---------------------------------------------------------------------------
#
# The paged twin of the dense decode cache (`serve/paged.py`): K/V rows
# live in fixed-size pages of a preallocated pool leaf shaped
# (n_pages, page_size, *rest), and a per-request block table maps view
# positions to pages. The three helpers below are the only array ops the
# paging layer needs — gather a contiguous attention view through the
# block table, and scatter freshly written rows back (one row per lane
# after a decode step, whole pages after a prefill). Both decode paths
# (`decode_attention` linear masking, `decode_attention_ring` modulo
# slots) run UNCHANGED on the gathered view; a ring leaf's view is
# sliced to exactly its window so the ring path triggers as on the
# dense cache. Page 0 is reserved as a scratch target: block-table
# entries past a request's allocation (and whole rows for empty lanes)
# point at it, and the positions they back are always masked, so their
# contribution to the softmax is exactly zero — which is why the paged
# view is BIT-identical to the dense path, not merely close.


def gather_page_view(pool, block_table, *, batch_ax, seq_ax, seq_len):
    """Materialize one leaf's dense attention view through a block table.

    ``pool``: (n_pages, page_size, *rest); ``block_table``: (L, Q) int32
    page ids per lane. Returns the leaf laid out exactly as its dense
    twin — lanes at ``batch_ax``, sequence at ``seq_ax`` — with view
    length ``min(seq_len, Q*page_size)``: a ring leaf (seq_len = W) is
    sliced to exactly W so the ring decode path triggers; a linear leaf
    only spans the pages actually allocated, which is the paged path's
    compute saving over a dense max_len cache."""
    L, Q = block_table.shape
    ps = pool.shape[1]
    v = pool[block_table]                            # (L, Q, ps, *rest)
    v = v.reshape((L, Q * ps) + pool.shape[2:])
    v = v[:, :min(seq_len, Q * ps)]
    return jnp.moveaxis(v, (0, 1), (batch_ax, seq_ax))


def scatter_page_token(pool, view, block_table, pos, *, batch_ax, seq_ax):
    """Write each lane's one decoded K/V row back to its page.

    ``pos`` is the (L,) absolute cache position the decode step wrote;
    the view row is ``pos % view_len`` — the identity for a linear view
    (pos < view_len always) and the ring slot for a ring view, so one
    formula covers both cache kinds. Lanes whose write lands on the
    scratch page (empty/padded lanes) collide there harmlessly: scratch
    rows only ever back masked positions."""
    ps = pool.shape[1]
    vm = jnp.moveaxis(view, (batch_ax, seq_ax), (0, 1))  # (L, Sv, *rest)
    L, sv = vm.shape[0], vm.shape[1]
    lanes = jnp.arange(L)
    p = pos % sv
    rows = vm[lanes, p]                              # (L, *rest)
    page = block_table[lanes, p // ps]
    return pool.at[page, p % ps].set(rows.astype(pool.dtype))


def scatter_page_prefill(pool, view, block_table, *, batch_ax, seq_ax):
    """Write a freshly prefilled view into pages — whole pages at a time.

    This is what the dense engine's masked slot-merge collapses into
    under paging: instead of `where(mask, new, old)` over a full
    (slots, max_len) cache, the new rows are simply ASSIGNED to the
    pages the block table names. The view is padded up to a whole page
    and every covered page is overwritten; rows past a request's
    allocation land on scratch."""
    ps = pool.shape[1]
    vm = jnp.moveaxis(view, (batch_ax, seq_ax), (0, 1))  # (L, Sv, *rest)
    L, sv = vm.shape[0], vm.shape[1]
    npg = -(-sv // ps)
    pad = npg * ps - sv
    if pad:
        vm = jnp.pad(vm, ((0, 0), (0, pad)) + ((0, 0),) * (vm.ndim - 2))
    vm = vm.reshape((L, npg, ps) + vm.shape[2:])
    return pool.at[block_table[:, :npg]].set(vm.astype(pool.dtype))


# ---------------------------------------------------------------------------
# Full attention block
# ---------------------------------------------------------------------------

def qkv_project(params, x, cfg):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dke->bske", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dke->bske", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def out_project(params, o, x_dtype, cfg):
    # mask padded heads: exactly-zero output AND gradients for the padding
    o = o * head_mask(cfg, o.dtype)[None, None, :, None]
    out = jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(o.dtype))
    if "bo" in params:
        out = out + params["bo"].astype(out.dtype)
    return out.astype(x_dtype)


def attention_block(params, x, *, cfg, positions, causal=True, cross_kv=None,
                    cache=None, cache_len=None):
    """One attention sub-layer (no norm/residual — the caller owns those).

    Returns (out, new_cache) where new_cache is None unless caching.
      * train/prefill: x is (B,S,d); if cache is provided (prefill) the fresh
        K/V are written at [0:S].
      * decode: x is (B,1,d); cache required.
      * cross_kv=(k,v) precomputed encoder keys/values => cross-attention.
    """
    if cross_kv is not None:
        k, v = cross_kv
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
        if "bq" in params:
            q = q + params["bq"].astype(x.dtype)
        o = blockwise_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        return out_project(params, o, x.dtype, cfg), None

    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope_style != "none":
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       style=cfg.rope_style, sections=cfg.mrope_sections)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       style=cfg.rope_style, sections=cfg.mrope_sections)

    if cache is not None and x.shape[1] == 1:  # decode
        k_cache, v_cache = cache
        B = x.shape[0]
        S_cache = k_cache.shape[1]
        cl = jnp.broadcast_to(jnp.atleast_1d(cache_len), (B,))
        rows = jnp.arange(B)
        if cfg.sliding_window and S_cache == cfg.sliding_window:
            # ring buffer: slot i holds the key of absolute position p with
            # p == i (mod W); new token at cache_len lands in slot cl % W
            slot = cl % S_cache
            k_cache = k_cache.at[rows, slot].set(k[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[rows, slot].set(v[:, 0].astype(v_cache.dtype))
            o = decode_attention_ring(q, k_cache, v_cache, cl)
        else:
            k_cache = k_cache.at[rows, cl].set(k[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[rows, cl].set(v[:, 0].astype(v_cache.dtype))
            o = decode_attention(q, k_cache, v_cache, cl,
                                 window=cfg.sliding_window)
        return out_project(params, o, x.dtype, cfg), (k_cache, v_cache)

    o = blockwise_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    new_cache = None
    if cache is not None:  # prefill into cache
        k_cache, v_cache = cache
        S_cache = k_cache.shape[1]
        S = k.shape[1]
        if cfg.sliding_window and S_cache == cfg.sliding_window:
            # ring prefill: keep the last W keys, rotated so that the key of
            # absolute position p sits in slot p % W
            W = S_cache
            if S >= W:
                tail_k, tail_v = k[:, -W:], v[:, -W:]
                shift = (S - W) % W
            else:
                pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
                tail_k, tail_v = jnp.pad(k, pad), jnp.pad(v, pad)
                shift = 0
            k_cache = jnp.roll(tail_k.astype(k_cache.dtype), shift, axis=1)
            v_cache = jnp.roll(tail_v.astype(v_cache.dtype), shift, axis=1)
        else:
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, 0, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, 0, 0, 0))
        new_cache = (k_cache, v_cache)
    return out_project(params, o, x.dtype, cfg), new_cache


def reference_attention(q, k, v, *, causal=True, window=None):
    """O(S^2)-memory oracle for tests."""
    B, Sq, H, dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32))
    s = s / np.sqrt(dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)

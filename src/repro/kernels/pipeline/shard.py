"""Multi-column sharding for the fused biosignal pipeline.

VWR2A scales throughput by replicating columns: the CGRA deals passes
round-robin across identical column slices that share the scratchpad
crossbar, and archsim's `VWR2A(n_columns=...)` models exactly that
(conserved activity, ~1/D cycles). This module is the Pallas-path
analogue: a `data`-axis `shard_map` around `pipeline_pallas` /
`pipeline_stream_pallas` that deals frame-blocks across devices the way
the simulator deals passes across columns.

The raw-signal split happens on HOP boundaries: column d owns the
contiguous run of frames [d*n_d, (d+1)*n_d) (n_d = ceil(n_frames / D) —
the same conserved-work deal as archsim's round-robin, collapsed to one
run per column so the inter-column halo stays minimal), and its chunk is

    signal[d*n_d*hop : d*n_d*hop + n_d*hop + (window - hop)]

i.e. each column stages ~n_samples/D body samples plus ONE `window-hop`
overlap halo replicated from its right neighbour — the inter-device
mirror of the in-kernel overlap sharing (PR 3), which keeps per-device
HBM traffic at ~n_samples/D instead of n_frames*window/D.

Every column runs the SAME single-device kernel on its chunk, so sharded
outputs are bit-identical to the unsharded call (each frame's pipeline
reads only its own window: the chunk FIR's frame-local transient patch
makes frames independent of how chunks are cut). When no mesh is
available (or D exceeds the device count) the identical per-column body
runs serially on one device — the fallback tests rely on for
device-count-independent equivalence properties.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.pipeline.kernel import (OUTPUTS, canonical_outputs,
                                           empty_outputs, pipeline_pallas,
                                           pipeline_stream_pallas,
                                           stream_frame_count)

__all__ = ["column_frames", "column_chunks", "pipeline_sharded",
           "pipeline_stream_sharded", "data_mesh_size"]


def data_mesh_size(mesh) -> int:
    """Size of the mesh's `data` axis (the column-replication axis)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)


def _check_mesh(mesh, n_columns: int) -> None:
    """`mesh=None` means the serial fallback by design, but a PROVIDED
    mesh whose data axis doesn't match n_columns is a misconfiguration —
    silently running serial would hand back single-device throughput with
    zero diagnostics."""
    assert mesh is None or data_mesh_size(mesh) == n_columns, (
        f"mesh data axis {data_mesh_size(mesh)} != n_columns {n_columns}; "
        f"build the mesh with make_local_mesh(data=n_columns) or pass "
        f"mesh=None for the serial fallback")


def column_frames(n_frames: int, n_columns: int) -> int:
    """Frames per column: the conserved-work deal. Every column processes
    the same padded count (shard_map shards must agree on shape); the
    `n_columns*column_frames - n_frames` pad frames are trimmed after."""
    assert n_columns >= 1, n_columns
    return -(-max(n_frames, 1) // n_columns)


def column_chunks(signal, window: int, hop: int, n_columns: int):
    """Split a raw 1-D signal into per-column chunks on hop boundaries.

    Returns `(chunks, n_frames)` where chunks is `(D, L)` with
    `L = n_d*hop + window - hop`: row d starts at sample `d*n_d*hop` and
    carries its `window-hop` right-halo (replicated from the neighbour's
    first samples), zero-padded past the signal end — so row d frames to
    exactly `n_d` windows, the ones frame-global indices
    [d*n_d, (d+1)*n_d) would produce. `n_frames == 0` yields (None, 0).
    """
    sig = jnp.asarray(signal)
    assert sig.ndim == 1, sig.shape
    n = stream_frame_count(sig.shape[0], window, hop)
    if n == 0:
        return None, 0
    n_d = column_frames(n, n_columns)
    L = n_d * hop + (window - hop)
    total = (n_columns - 1) * n_d * hop + L
    if total > sig.shape[0]:
        sig = jnp.concatenate(
            [sig, jnp.zeros((total - sig.shape[0],), sig.dtype)])
    chunks = jnp.stack([sig[d * n_d * hop: d * n_d * hop + L]
                        for d in range(n_columns)])
    return chunks, n


def _trim(out: dict, n: int) -> dict:
    return {k: v[:n] for k, v in out.items()}


def _stream_body(chunk, taps, w, b, *, window, hop, fft_size, interpret,
                 block_frames, outputs):
    """One column's work: the unsharded single-device kernel on a (1, L)
    chunk row. Shared verbatim by the shard_map shard and the serial
    fallback, which is what makes the two paths bit-identical."""
    return pipeline_stream_pallas(
        chunk[0], taps, w, b, window=window, hop=hop, fft_size=fft_size,
        interpret=interpret, block_frames=block_frames, outputs=outputs)


@functools.lru_cache(maxsize=64)
def _stream_shard_fn(mesh, window, hop, fft_size, interpret, block_frames,
                     outputs):
    """Memoized jit(shard_map(...)) per (mesh, static config): an eager
    shard_map re-traces every dispatch, which would swamp the per-batch
    runtime; Mesh hashes by value, so every stream with the same column
    layout shares one compiled executable."""
    body = functools.partial(_stream_body, window=window, hop=hop,
                             fft_size=fft_size, interpret=interpret,
                             block_frames=block_frames, outputs=outputs)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("data"), P(), P(), P()),
        out_specs=P("data"),
        check_rep=False))         # pallas_call has no replication rule


def pipeline_stream_sharded(signal, taps, w, b, *, window: int, hop: int,
                            n_columns: int, mesh=None, fft_size: int = 512,
                            interpret: bool = True,
                            block_frames: int | None = None,
                            outputs: tuple = OUTPUTS):
    """`pipeline_stream_pallas` dealt across `n_columns` column replicas.

    With `mesh` (a mesh whose `data` axis has >= n_columns devices... in
    fact exactly n_columns — build it with
    `launch.mesh.make_local_mesh(data=n_columns)`), the per-column chunks
    are `shard_map`ped over the `data` axis: each device stages only its
    ~n_samples/D chunk + halo and runs the fused kernel on it. Without a
    mesh the same per-column body runs serially — identical outputs, so
    every equivalence property is testable on a single device.
    """
    outputs = canonical_outputs(outputs)
    _check_mesh(mesh, n_columns)
    F, C = w.shape
    chunks, n = column_chunks(signal, window, hop, n_columns)
    if n == 0:
        return empty_outputs(window, F, C, jnp.asarray(signal).dtype,
                             outputs)
    body = functools.partial(_stream_body, window=window, hop=hop,
                             fft_size=fft_size, interpret=interpret,
                             block_frames=block_frames, outputs=outputs)
    if n_columns == 1:
        return _trim(body(chunks, taps, w, b), n)
    if mesh is not None:
        sharded = _stream_shard_fn(mesh, window, hop, fft_size, interpret,
                                   block_frames, outputs)
        return _trim(sharded(chunks, taps, w, b), n)
    # serial-column fallback: same deal, one device
    outs = [body(chunks[d: d + 1], taps, w, b) for d in range(n_columns)]
    return _trim({k: jnp.concatenate([o[k] for o in outs]) for k in outs[0]},
                 n)


def _framed_body(rows, taps, w, b, *, fft_size, interpret, block_rows,
                 outputs):
    return pipeline_pallas(rows, taps, w, b, fft_size=fft_size,
                           interpret=interpret, block_rows=block_rows,
                           outputs=outputs)


@functools.lru_cache(maxsize=64)
def _framed_shard_fn(mesh, fft_size, interpret, block_rows, outputs):
    body = functools.partial(_framed_body, fft_size=fft_size,
                             interpret=interpret, block_rows=block_rows,
                             outputs=outputs)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("data"), P(), P(), P()),
        out_specs=P("data"),
        check_rep=False))         # pallas_call has no replication rule


def pipeline_sharded(frames, taps, w, b, *, n_columns: int, mesh=None,
                     fft_size: int = 512, interpret: bool = True,
                     block_rows: int | None = None,
                     outputs: tuple = OUTPUTS):
    """`pipeline_pallas` on pre-framed (R, S) windows, rows dealt across
    columns: row-block d of ceil(R/D) windows goes to column d (pad rows
    are trimmed after). The framed counterpart of
    `pipeline_stream_sharded` — no halo needed, frames carry their own
    overlap."""
    outputs = canonical_outputs(outputs)
    _check_mesh(mesh, n_columns)
    R, S = frames.shape
    F, C = w.shape
    if R == 0:
        return empty_outputs(S, F, C, frames.dtype, outputs)
    body = functools.partial(_framed_body, fft_size=fft_size,
                             interpret=interpret, block_rows=block_rows,
                             outputs=outputs)
    if n_columns == 1:
        return body(frames, taps, w, b)
    r_d = column_frames(R, n_columns)
    if n_columns * r_d > R:
        frames = jnp.concatenate(
            [frames, jnp.zeros((n_columns * r_d - R, S), frames.dtype)])
    if mesh is not None:
        sharded = _framed_shard_fn(mesh, fft_size, interpret, block_rows,
                                   outputs)
        return _trim(sharded(frames, taps, w, b), R)
    outs = [body(frames[d * r_d: (d + 1) * r_d], taps, w, b)
            for d in range(n_columns)]
    return _trim({k: jnp.concatenate([o[k] for o in outs]) for k in outs[0]},
                 R)

"""Pure-jnp oracle for the FFT kernel: core/fft's validated Stockham path
(itself validated against np.fft to ~1e-7 relative)."""
from __future__ import annotations

from repro.core.fft import fft as fft_ref            # noqa: F401
from repro.core.fft import rfft_packed as rfft_ref   # noqa: F401

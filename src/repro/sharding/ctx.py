"""Logical activation-sharding constraints (context-scoped).

XLA SPMD propagates operand shardings, but conflicts make it drop them: the
FSDP-sharded embedding table (embed -> data) meets the batch-sharded token
ids (batch -> data) at the very first gather, and the batch sharding LOSES —
every activation downstream is then replicated over the data axis (found via
the §Roofline byte dissection: global-batch-shaped tensors in the per-device
HLO). The standard fix (MaxText-style) is explicit logical constraints on
activations.

The step factories install a spec table for the current mesh; model code
calls ``constrain(x, "btd")`` etc. — a no-op outside any installed context,
so smoke tests and CPU examples are unaffected.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_SPECS: Optional[dict] = None


def make_activation_specs(mesh, strategy: str = "train") -> dict:
    names = set(mesh.axis_names)
    if strategy in ("fsdp", "serve_fsdp"):
        dp = tuple(a for a in ("pod", "data", "model") if a in names)
        tp = None            # weights are gathered; no TP-sharded activations
    else:
        dp = tuple(a for a in ("pod", "data") if a in names)
        tp = "model" if "model" in names else None
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    return {
        # (batch, seq, d_model) activations: batch over DP, rest replicated
        "btd": NamedSharding(mesh, P(dp_entry, None, None)),
        # (batch, seq) token planes
        "bt": NamedSharding(mesh, P(dp_entry, None)),
        # (batch, seq, vocab) logits: vocab over TP
        "btv": NamedSharding(mesh, P(dp_entry, None, tp)),
        # (batch, seq, heads, head_dim): heads over TP
        "bthd": NamedSharding(mesh, P(dp_entry, None, tp, None)),
    }


@contextlib.contextmanager
def activation_sharding(mesh, strategy: str = "train"):
    global _SPECS
    prev = _SPECS
    _SPECS = make_activation_specs(mesh, strategy)
    try:
        yield
    finally:
        _SPECS = prev


def install(mesh, strategy: str = "train"):
    """Non-contextual install (step factories trace inside jit.lower)."""
    global _SPECS
    _SPECS = make_activation_specs(mesh, strategy) if mesh is not None \
        else None


def constrain(x, kind: str):
    if _SPECS is None or kind not in _SPECS:
        return x
    sh = _SPECS[kind]
    if x.ndim != len(sh.spec):
        return x
    return jax.lax.with_sharding_constraint(x, sh)
